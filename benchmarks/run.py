"""Benchmark runner: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines. CPU wall-clock timings are
relative claims only (DESIGN.md §9); the TPU performance story lives in
EXPERIMENTS.md §Roofline/§Perf (from the compiled dry-run).

Usage:
    python -m benchmarks.run [--help] [--emit-json] [--small] [filter]

With a ``filter`` argument, only suites whose name contains the substring
run. ``--emit-json`` additionally persists machine-readable artifacts:
every suite's emit() rows are written as a schema-validated
``BENCH_<suite>.json`` in the shared ``tempest-bench/v1`` layout
(repro.obs.export.bench_doc, DESIGN.md §16); suites with extra detail
payloads (fused_walks -> BENCH_fused.json, fig7 -> BENCH_shard.json)
keep those artifact names, wrapped in the same schema. ``--small``
shrinks suite configs to nightly-CI scale. ``--help`` lists every suite
with its paper counterpart (the same set documented in
benchmarks/README.md).
"""
from __future__ import annotations

import sys
import traceback

# (suite name, module name, paper counterpart, one-line description)
SUITES = [
    ("table2_scheduler_ablation", "ablation_scheduler", "Table 2 / Fig. 8",
     "walks/s across scheduler paths incl. per-hop regroup old-vs-new "
     "(lexsort vs bucket) + modeled HBM traffic"),
    ("table3_tier_distribution", "tier_distribution", "Table 3",
     "dispatch-plane tier statistics over the (W, G) grid"),
    ("table4_ingestion_breakdown", "ingestion_breakdown", "Table 4",
     "per-batch ingestion stage breakdown + sort-vs-merge advance"),
    ("table5_tea_baseline", "baseline_tea", "Table 5",
     "Tempest vs TEA-style CPU temporal-walk baseline"),
    ("table6_validity_static", "validity_static", "Table 6",
     "causal validity: temporal engine vs static walker"),
    ("fig6_streaming_replay", "streaming_replay", "Fig. 6",
     "streaming replay latency/headroom; 3 drivers old-vs-new throughput"),
    ("fig7_scaling_edges", "scaling_edges", "Fig. 7",
     "ingest + walk cost vs active edge count; node-partitioned-window "
     "replay throughput vs shard count (DESIGN.md §12)"),
    ("fig8_9_param_sweeps", "param_sweeps", "Figs. 8-9",
     "tile_walks/tile_edges (block-dim analog) + solo_threshold sweeps"),
    ("fig10_window_sensitivity", "window_sensitivity", "Fig. 10",
     "window duration sweep: active edges, drops, per-batch cost"),
    ("fig11_memory_usage", "memory_usage", "Fig. 11",
     "device bytes across a stream (exactly constant) + accounting"),
    ("fused_walk_paths", "fused_walks", "Tables 2-3 (§14)",
     "walks/s across all five walk paths (fullwalk / grouped-lexsort / "
     "grouped-bucket / tiled / fused) + fused per-tier launch counts; "
     "--emit-json writes BENCH_fused.json"),
    ("serving_load", "serving_load", "— (§11, §13, §18)",
     "serving SLO harness: open-loop Poisson load curves (p50/p99 + "
     "goodput under deadlines) blocking vs overlapped async runtime, "
     "closed-loop drain throughput, and the sharded-service sweep vs "
     "shard count (--shards; needs "
     "XLA_FLAGS=--xla_force_host_platform_device_count=8 for multi-shard "
     "rows on CPU); --emit-json writes BENCH_serving.json"),
]


def _print_help() -> None:
    print(__doc__.strip())
    print("\nSuites:")
    width = max(len(n) for n, *_ in SUITES)
    for name, _mod, paper, desc in SUITES:
        print(f"  {name:<{width}}  {paper:<9} {desc}")


def main() -> None:
    if any(a in ("-h", "--help") for a in sys.argv[1:]):
        _print_help()
        return

    import importlib

    from benchmarks import common

    argv = sys.argv[1:]
    if "--emit-json" in argv:
        common.EMIT_JSON = True
        argv = [a for a in argv if a != "--emit-json"]
    if "--small" in argv:
        common.SMALL = True
        argv = [a for a in argv if a != "--small"]

    only = argv[0] if argv else None
    failed = []
    for name, mod_name, _paper, _desc in SUITES:
        if only and only not in name:
            continue
        print(f"# --- {name} ---", flush=True)
        common.begin_suite(name)
        try:
            importlib.import_module(f"benchmarks.{mod_name}").run()
            common.end_suite()
        except Exception:
            traceback.print_exc()
            failed.append(name)
    if failed:
        print(f"# FAILED: {failed}")
        sys.exit(1)
    print("# all benchmark suites completed")


if __name__ == "__main__":
    main()
