"""Benchmark runner: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines. CPU wall-clock timings are
relative claims only (DESIGN.md §9); the TPU performance story lives in
EXPERIMENTS.md §Roofline/§Perf (from the compiled dry-run).
"""
from __future__ import annotations

import sys
import traceback


def main() -> None:
    from benchmarks import (
        ablation_scheduler,
        baseline_tea,
        ingestion_breakdown,
        memory_usage,
        param_sweeps,
        scaling_edges,
        streaming_replay,
        tier_distribution,
        validity_static,
        window_sensitivity,
    )

    suites = [
        ("table2_scheduler_ablation", ablation_scheduler.run),
        ("table3_tier_distribution", tier_distribution.run),
        ("table4_ingestion_breakdown", ingestion_breakdown.run),
        ("table5_tea_baseline", baseline_tea.run),
        ("table6_validity_static", validity_static.run),
        ("fig6_streaming_replay", streaming_replay.run),
        ("fig7_scaling_edges", scaling_edges.run),
        ("fig8_9_param_sweeps", param_sweeps.run),
        ("fig10_window_sensitivity", window_sensitivity.run),
        ("fig11_memory_usage", memory_usage.run),
    ]
    only = sys.argv[1] if len(sys.argv) > 1 else None
    failed = []
    for name, fn in suites:
        if only and only not in name:
            continue
        print(f"# --- {name} ---", flush=True)
        try:
            fn()
        except Exception:
            traceback.print_exc()
            failed.append(name)
    if failed:
        print(f"# FAILED: {failed}")
        sys.exit(1)
    print("# all benchmark suites completed")


if __name__ == "__main__":
    main()
