"""Paper Fig. 6: cumulative streaming performance (scaled-down replay).

Chronological batches under a sliding window; per-batch ingest + sampling
latency vs. the batch arrival interval gives the real-time headroom factor
(paper: 235x on Alibaba).

Three drivers over the same stream (old vs new, DESIGN.md §4):

* ``sort``  — seed baseline: host loop + concat/argsort ingest.
* ``merge`` — host loop + rank-based two-run merge ingest (donated buffers).
* ``scan``  — merge ingest under ``replay_scan``: the whole replay is one
  ``jax.lax.scan`` on device, single host sync at the end.

Emits per-driver ingest throughput (edges/s) and batches/s.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit
from repro.configs.base import (
    EngineConfig,
    SamplerConfig,
    SchedulerConfig,
    WalkConfig,
    WindowConfig,
)
from repro.core.streaming import StreamingEngine
from repro.data.synthetic import chronological_batches, powerlaw_temporal_graph


def _config(num_nodes):
    return EngineConfig(
        window=WindowConfig(duration=3000, edge_capacity=1 << 17,
                            node_capacity=num_nodes),
        sampler=SamplerConfig(bias="exponential", mode="index"),
        scheduler=SchedulerConfig(path="grouped"),
    )


def run(num_nodes=2048, num_edges=200_000, batches=24,
        arrival_interval_s=1.0):
    g = powerlaw_temporal_graph(num_nodes, num_edges, seed=9,
                                ts_groups=512)
    cfg = _config(num_nodes)
    bcap = num_edges // batches + 64
    wcfg = WalkConfig(num_walks=4096, max_length=20, start_mode="nodes")

    # ---- driver 1 (seed baseline): host loop, sort-based ingest ----------
    eng_sort = StreamingEngine(cfg, batch_capacity=bcap, ingest_impl="sort")
    stats_sort = eng_sort.replay(chronological_batches(g, batches), wcfg)

    # ---- driver 2: host loop, merge-based ingest -------------------------
    eng = StreamingEngine(cfg, batch_capacity=bcap, ingest_impl="merge")
    stats = eng.replay(chronological_batches(g, batches), wcfg)

    # ---- driver 3: device-resident scan (merge ingest, one host sync) ----
    eng_scan = StreamingEngine(cfg, batch_capacity=bcap)
    # warm-up/compile on the first run, measure the second
    eng_scan.replay_device(chronological_batches(g, batches), wcfg)
    eng_scan2 = StreamingEngine(cfg, batch_capacity=bcap)
    rstats, scan_s = eng_scan2.replay_device(
        chronological_batches(g, batches), wcfg)

    # headline (kept from seed): steady-state per-batch latency, merge loop
    ing = np.asarray(stats.ingest_s[1:])     # skip compile batch
    smp = np.asarray(stats.sample_s[1:])
    per_batch = ing.mean() + smp.mean()
    headroom = arrival_interval_s / per_batch
    emit("fig6/streaming", per_batch * 1e6,
         f"ingest_ms={1e3*ing.mean():.1f};sample_ms={1e3*smp.mean():.1f};"
         f"headroom={headroom:.0f}x;"
         f"linear_ingest_r2={_linearity(stats.cumulative_ingest):.4f}")

    # old-vs-new throughput + batches/s for all three drivers. The host
    # loops time ingest in isolation (ingest_edges_per_s); the scan driver's
    # step is fused ingest+walk and cannot be split, so its per-edge rate is
    # emitted under a different key (step_edges_per_s) — only batches_per_s
    # is apples-to-apples across all three.
    edges_per_batch = num_edges / batches
    ing_sort = np.asarray(stats_sort.ingest_s[1:])
    for name, step_mean, batch_s, rate_key in (
            ("sort_hostloop", ing_sort.mean(),
             1.0 / (ing_sort.mean() + np.asarray(stats_sort.sample_s[1:]).mean()),
             "ingest_edges_per_s"),
            ("merge_hostloop", ing.mean(), 1.0 / per_batch,
             "ingest_edges_per_s"),
            ("merge_scan", scan_s / batches, batches / scan_s,
             "step_edges_per_s")):
        note = ";fused_step=ingest+walk" if name == "merge_scan" else ""
        emit(f"fig6/ingest_{name}", step_mean * 1e6,
             f"{rate_key}={edges_per_batch/step_mean:.3e};"
             f"batches_per_s={batch_s:.2f}" + note)
    emit("fig6/merge_vs_sort_ingest_speedup",
         1e6 * (ing_sort.mean() - ing.mean()),
         f"speedup={ing_sort.mean()/ing.mean():.2f}x")
    return stats


def _linearity(cum) -> float:
    """R^2 of cumulative-vs-batch linear fit (paper: 'essentially linear',
    confirming cost does not accumulate)."""
    y = np.asarray(cum, dtype=np.float64)
    x = np.arange(len(y), dtype=np.float64)
    if len(y) < 3:
        return 1.0
    A = np.stack([x, np.ones_like(x)], 1)
    coef, *_ = np.linalg.lstsq(A, y, rcond=None)
    resid = y - A @ coef
    ss_res = float(np.sum(resid ** 2))
    ss_tot = float(np.sum((y - y.mean()) ** 2))
    return 1.0 - ss_res / max(ss_tot, 1e-12)


if __name__ == "__main__":
    run()
