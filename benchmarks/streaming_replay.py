"""Paper Fig. 6: cumulative streaming performance (scaled-down replay).

Chronological batches under a sliding window; per-batch ingest + sampling
latency vs. the batch arrival interval gives the real-time headroom factor
(paper: 235x on Alibaba).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit
from repro.configs.base import (
    EngineConfig,
    SamplerConfig,
    SchedulerConfig,
    WalkConfig,
    WindowConfig,
)
from repro.core.streaming import StreamingEngine
from repro.data.synthetic import chronological_batches, powerlaw_temporal_graph


def run(num_nodes=2048, num_edges=200_000, batches=24,
        arrival_interval_s=1.0):
    g = powerlaw_temporal_graph(num_nodes, num_edges, seed=9,
                                ts_groups=512)
    cfg = EngineConfig(
        window=WindowConfig(duration=3000, edge_capacity=1 << 17,
                            node_capacity=num_nodes),
        sampler=SamplerConfig(bias="exponential", mode="index"),
        scheduler=SchedulerConfig(path="grouped"),
    )
    eng = StreamingEngine(cfg, batch_capacity=num_edges // batches + 64)
    wcfg = WalkConfig(num_walks=4096, max_length=20, start_mode="nodes")
    stats = eng.replay(chronological_batches(g, batches), wcfg)

    ing = np.asarray(stats.ingest_s[1:])     # skip compile batch
    smp = np.asarray(stats.sample_s[1:])
    per_batch = ing.mean() + smp.mean()
    headroom = arrival_interval_s / per_batch
    emit("fig6/streaming", per_batch * 1e6,
         f"ingest_ms={1e3*ing.mean():.1f};sample_ms={1e3*smp.mean():.1f};"
         f"headroom={headroom:.0f}x;"
         f"linear_ingest_r2={_linearity(stats.cumulative_ingest):.4f}")
    return stats


def _linearity(cum) -> float:
    """R^2 of cumulative-vs-batch linear fit (paper: 'essentially linear',
    confirming cost does not accumulate)."""
    y = np.asarray(cum, dtype=np.float64)
    x = np.arange(len(y), dtype=np.float64)
    if len(y) < 3:
        return 1.0
    A = np.stack([x, np.ones_like(x)], 1)
    coef, *_ = np.linalg.lstsq(A, y, rcond=None)
    resid = y - A @ coef
    ss_res = float(np.sum(resid ** 2))
    ss_tot = float(np.sum((y - y.mean()) ** 2))
    return 1.0 - ss_res / max(ss_tot, 1e-12)


if __name__ == "__main__":
    run()
