"""Paper Table 4: per-batch ingestion time breakdown.

Stages (TPU/CPU analog of the paper's NVTX ranges):
  sort     — timestamp sort of the incoming batch + store merge sort
  weight   — cumulative-weight prefix construction (the fused kernel path)
  h2d      — host->device transfer of the raw batch
  pipeline — everything else in the jitted ingest (offsets, eviction,
             gathers) + dispatch overhead
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core.edge_store import make_batch
from repro.core.window import ingest, init_window
from repro.data.synthetic import chronological_batches, powerlaw_temporal_graph
from repro.kernels.weight_prefix import weight_prefix


def run(num_nodes=2048, num_edges=120_000, batches=12):
    g = powerlaw_temporal_graph(num_nodes, num_edges, seed=3)
    state = init_window(edge_capacity=131072, node_capacity=num_nodes,
                        window=4000)
    bcap = num_edges // batches + 64

    t_sort = t_weight = t_h2d = t_total = 0.0
    n = 0
    for bs, bd, bt in chronological_batches(g, batches):
        # h2d: batch padding + device_put
        t0 = time.perf_counter()
        batch = make_batch(bs, bd, bt, capacity=bcap)
        jax.block_until_ready(batch.src)
        t_h2d += time.perf_counter() - t0

        # isolated sort stage (what the radix sorts cost standalone)
        t0 = time.perf_counter()
        s = jnp.sort(batch.ts)
        jax.block_until_ready(s)
        t_sort += time.perf_counter() - t0

        # isolated weight stage on the current store
        idx = state.index
        nc = idx.node_capacity
        dt = (idx.ns_ts - idx.node_tref[jnp.clip(idx.ns_src, 0, nc - 1)])
        t0 = time.perf_counter()
        p = weight_prefix(dt.astype(jnp.float32), idx.ns_src < nc,
                          tile=1024)
        jax.block_until_ready(p)
        t_weight += time.perf_counter() - t0

        # full pipeline
        t0 = time.perf_counter()
        state = ingest(state, batch, num_nodes)
        jax.block_until_ready(state.index.ns_order)
        t_total += time.perf_counter() - t0
        n += 1

    total_ms = 1e3 * (t_total + t_h2d + t_sort + t_weight) / n
    pipeline = t_total - t_sort - t_weight
    parts = {
        "sort": t_sort, "weight": t_weight, "h2d": t_h2d,
        "pipeline": max(pipeline, 0.0),
    }
    tot = sum(parts.values())
    emit("table4/breakdown", 1e6 * tot / n,
         ";".join(f"{k}={100*v/tot:.1f}%" for k, v in parts.items())
         + f";total_ms={1e3*tot/n:.1f}")
    return parts


if __name__ == "__main__":
    run()
