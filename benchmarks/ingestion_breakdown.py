"""Paper Table 4: per-batch ingestion time breakdown.

Stages (TPU/CPU analog of the paper's NVTX ranges):
  sort     — timestamp sort of the incoming batch (the store-side merge is
             rank-based, DESIGN.md §4, measured in the sort-vs-merge emit)
  weight   — cumulative-weight prefix construction (the fused kernel path)
  h2d      — host->device transfer of the raw batch
  pipeline — everything else in the jitted ingest (merge ranks, eviction,
             gathers, index rebuild) + dispatch overhead
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core.edge_store import make_batch
from repro.core.window import ingest, ingest_sort, init_window
from repro.data.synthetic import chronological_batches, powerlaw_temporal_graph
from repro.kernels.weight_prefix import weight_prefix


def run(num_nodes=2048, num_edges=120_000, batches=12,
        edge_capacity=131072, window=4000):
    g = powerlaw_temporal_graph(num_nodes, num_edges, seed=3)
    state = init_window(edge_capacity=edge_capacity, node_capacity=num_nodes,
                        window=window)
    bcap = num_edges // batches + 64

    t_sort = t_weight = t_h2d = t_total = 0.0
    n = 0
    for bs, bd, bt in chronological_batches(g, batches):
        # h2d: batch padding + device_put
        t0 = time.perf_counter()
        batch = make_batch(bs, bd, bt, capacity=bcap)
        jax.block_until_ready(batch.src)
        t_h2d += time.perf_counter() - t0

        # isolated sort stage (what the radix sorts cost standalone)
        t0 = time.perf_counter()
        s = jnp.sort(batch.ts)
        jax.block_until_ready(s)
        t_sort += time.perf_counter() - t0

        # isolated weight stage on the current store
        idx = state.index
        nc = idx.node_capacity
        dt = (idx.ns_ts - idx.node_tref[jnp.clip(idx.ns_src, 0, nc - 1)])
        t0 = time.perf_counter()
        p = weight_prefix(dt.astype(jnp.float32), idx.ns_src < nc,
                          tile=1024)
        jax.block_until_ready(p)
        t_weight += time.perf_counter() - t0

        # full pipeline
        t0 = time.perf_counter()
        state = ingest(state, batch, num_nodes)
        jax.block_until_ready(state.index.ns_order)
        t_total += time.perf_counter() - t0
        n += 1

    total_ms = 1e3 * (t_total + t_h2d + t_sort + t_weight) / n
    pipeline = t_total - t_sort - t_weight
    parts = {
        "sort": t_sort, "weight": t_weight, "h2d": t_h2d,
        "pipeline": max(pipeline, 0.0),
    }
    tot = sum(parts.values())
    emit("table4/breakdown", 1e6 * tot / n,
         ";".join(f"{k}={100*v/tot:.1f}%" for k, v in parts.items())
         + f";total_ms={1e3*tot/n:.1f}")
    _run_sort_vs_merge(g, num_nodes, num_edges, batches, bcap,
                       edge_capacity, window)
    return parts


def _run_sort_vs_merge(g, num_nodes, num_edges, batches, bcap,
                       edge_capacity, window):
    """Old-vs-new window advance: seed concat+argsort vs rank-based merge
    (DESIGN.md §4), identical stream, identical states."""
    timings = {}
    for name, fn in (("sort", ingest_sort), ("merge", ingest)):
        state = init_window(edge_capacity=edge_capacity,
                            node_capacity=num_nodes, window=window)
        per_batch_s = []
        for bs, bd, bt in chronological_batches(g, batches):
            batch = make_batch(bs, bd, bt, capacity=bcap)
            jax.block_until_ready(batch.src)
            t0 = time.perf_counter()
            state = fn(state, batch, num_nodes)
            jax.block_until_ready(state.index.ns_order)
            per_batch_s.append(time.perf_counter() - t0)
        # skip the compile batch when there is a steady state to report
        steady = per_batch_s[1:] if len(per_batch_s) > 1 else per_batch_s
        timings[name] = sum(steady) / len(steady)
    edges_per_batch = num_edges / batches
    for name, per_batch in timings.items():
        emit(f"table4/ingest_{name}", 1e6 * per_batch,
             f"edges_per_s={edges_per_batch/per_batch:.3e}")
    emit("table4/merge_speedup",
         1e6 * (timings["sort"] - timings["merge"]),
         f"speedup={timings['sort']/timings['merge']:.2f}x")


if __name__ == "__main__":
    run()
