"""Paper Table 5: comparison against a TEA/TEA+-style CPU engine
(hybrid alias sampling), implemented in core/baselines.py.

Configuration mirrors the paper: 1 walk per node, walk length 80,
{exponential, linear} bias + temporal node2vec.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import emit, make_bench_index, timeit
from repro.configs.base import SamplerConfig, SchedulerConfig, WalkConfig
from repro.core.baselines import TeaStyleSampler
from repro.core.walk_engine import generate_walks


def run(num_nodes=1024, num_edges=40000):
    g, idx = make_bench_index(num_nodes=num_nodes, num_edges=num_edges)
    L = 80
    cases = [("exponential", 1.0, 1.0), ("linear", 1.0, 1.0),
             ("node2vec", 0.5, 2.0)]
    rows = []
    for bias, p, q in cases:
        b = "exponential" if bias == "node2vec" else bias
        # --- TEA-style CPU baseline ---
        tea = TeaStyleSampler(g.src, g.dst, g.ts, num_nodes, bias=b)
        rng = np.random.default_rng(0)
        t0 = time.perf_counter()
        for v in range(num_nodes):
            tea.walk(v, -1, L, rng, p=p, q=q)
        t_tea = time.perf_counter() - t0

        # --- Tempest-JAX (bulk mode for parity, paper §3.8) ---
        wcfg = WalkConfig(num_walks=num_nodes, max_length=L,
                          start_mode="all_nodes")
        scfg = SamplerConfig(bias=b, mode="weight",
                             node2vec_p=p, node2vec_q=q)
        mean, _, _ = timeit(generate_walks, idx, jax.random.PRNGKey(0),
                            wcfg, scfg, SchedulerConfig(), repeats=3)
        speedup = t_tea / mean
        emit(f"table5/{bias}", mean * 1e6,
             f"tea_s={t_tea:.3f};tempest_s={mean:.3f};speedup={speedup:.1f}x")
        rows.append((bias, t_tea, mean, speedup))
    return rows


if __name__ == "__main__":
    run()
