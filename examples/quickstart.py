"""Quickstart: build a temporal graph, ingest it, sample causal walks.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import numpy as np

from repro.configs.base import SamplerConfig, SchedulerConfig, WalkConfig
from repro.core import build_index, store_from_arrays
from repro.core.validation import validate_walks
from repro.core.walk_engine import generate_walks
from repro.data.synthetic import powerlaw_temporal_graph


def main():
    # 1. a hub-skewed temporal graph (swap in your own (src, dst, ts))
    g = powerlaw_temporal_graph(num_nodes=500, num_edges=10_000, seed=42)

    # 2. the dual-index edge store (paper §2.3)
    store = store_from_arrays(g.src, g.dst, g.ts,
                              edge_capacity=16384, node_capacity=512)
    index = build_index(store, node_capacity=512)

    # 3. temporal random walks under an exponential recency bias
    walks = generate_walks(
        index, jax.random.PRNGKey(0),
        WalkConfig(num_walks=1024, max_length=80, start_mode="nodes"),
        SamplerConfig(bias="exponential", mode="weight"),
        SchedulerConfig(path="grouped"),
    )

    # 4. every hop is causal (paper §3.10: 100% valid)
    report = validate_walks(index, walks)
    lengths = np.asarray(walks.lengths)
    print(f"walks: {lengths.shape[0]}, mean length {lengths.mean():.1f}")
    print(f"hop validity  : {float(report.hop_valid_frac):.3f}")
    print(f"walk validity : {float(report.walk_valid_frac):.3f}")
    print("first walk:", np.asarray(walks.nodes)[0, :int(lengths[0])])
    print("its times  :", np.asarray(walks.times)[0, :int(lengths[0])])


if __name__ == "__main__":
    main()
