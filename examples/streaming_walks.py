"""Streaming ingestion under a sliding window (paper §3.3 regime),
plus the observability quickstart (DESIGN.md §16): both replay drivers
publish into one metrics registry, exported at the end as Prometheus
text, a JSON snapshot, and a streaming-health document.

    PYTHONPATH=src python examples/streaming_walks.py
"""
import json

import numpy as np

from repro.configs.base import (
    EngineConfig,
    SamplerConfig,
    SchedulerConfig,
    WalkConfig,
    WindowConfig,
)
from repro.core.streaming import StreamingEngine
from repro.core.validation import validate_walks
from repro.data.synthetic import chronological_batches, powerlaw_temporal_graph
from repro.obs import health_snapshot, new_registry, to_prometheus


def main():
    g = powerlaw_temporal_graph(num_nodes=1000, num_edges=100_000, seed=7)
    cfg = EngineConfig(
        window=WindowConfig(duration=2500, edge_capacity=1 << 16,
                            node_capacity=1024),
        sampler=SamplerConfig(bias="exponential", mode="index"),
        scheduler=SchedulerConfig(path="grouped"),
    )
    registry = new_registry()     # or omit: engines share the process default
    engine = StreamingEngine(cfg, batch_capacity=8192, registry=registry)
    wcfg = WalkConfig(num_walks=2048, max_length=30, start_mode="nodes")

    def on_batch(eng, walks):
        i = len(eng.stats.ingest_s)
        rep = validate_walks(eng.state.index, walks)
        print(f"batch {i:2d}: active_edges={eng.stats.edges_active[-1]:7d} "
              f"ingest={1e3*eng.stats.ingest_s[-1]:7.1f}ms "
              f"sample={1e3*eng.stats.sample_s[-1]:7.1f}ms "
              f"valid={float(rep.walk_valid_frac):.2f} "
              f"late={int(eng.state.late_drops)}")

    engine.replay(chronological_batches(g, 16), wcfg, on_batch=on_batch)
    ing = np.asarray(engine.stats.ingest_s[1:])
    print(f"\nsteady-state ingest {1e3*ing.mean():.1f}ms/batch; memory "
          f"bounded by the window (static shapes => exactly constant).")

    # Same replay, device-resident: all 16 batches run under one lax.scan
    # (merge ingest + fused walks, donated buffers) with a single host sync
    # at the end — the throughput driver (DESIGN.md §4).
    engine2 = StreamingEngine(cfg, batch_capacity=8192, registry=registry)
    stats, secs = engine2.replay_device(chronological_batches(g, 16), wcfg)
    print(f"device-resident replay: {len(stats.edges_active)} batches in "
          f"{secs:.2f}s incl. one-time jit compile "
          f"(see benchmarks/streaming_replay.py for warmed timings), "
          f"late={int(stats.late_drops[-1])} "
          f"overflow={int(stats.overflow_drops[-1])}")

    # Both drivers published into the same registry (the device replay's
    # probe counters flushed at its one existing host sync). One export
    # covers everything — DESIGN.md §16.
    print("\n--- Prometheus exposition (excerpt) ---")
    print("\n".join(l for l in to_prometheus(registry).splitlines()
                    if l.startswith(("stream_", "window_", "drops_"))))
    health = health_snapshot(registry)     # validated tempest-health/v1
    print("\n--- streaming health ---")
    print(json.dumps({k: health[k] for k in ("ingest", "window", "drops")},
                     indent=2, sort_keys=True))


if __name__ == "__main__":
    main()
