"""End-to-end driver #2: train an LM on walk-token sequences
(walk-native training, paper conclusion) with checkpoint/restart.

Default: a reduced olmo-1b topology for a few hundred CPU steps.
``--full`` uses the real olmo-1b config (~1B params; needs accelerators —
use launch/train.py with a mesh).

    PYTHONPATH=src python examples/train_lm_on_walks.py --steps 200
"""
import argparse
import os

import jax
import numpy as np

from repro.configs import get_config, reduced
from repro.configs.base import (
    EngineConfig,
    SamplerConfig,
    SchedulerConfig,
    WalkConfig,
    WindowConfig,
)
from repro.core.streaming import StreamingEngine
from repro.data.synthetic import chronological_batches, powerlaw_temporal_graph
from repro.data.walk_dataset import walks_to_lm_batch
from repro.models import model as M
from repro.train import checkpoint as ckpt
from repro.train.optimizer import AdamWConfig, init_opt_state
from repro.train.train_loop import make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/tempest_lm_ckpt")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    args = ap.parse_args()

    cfg = get_config("olmo-1b")
    if not args.full:
        cfg = reduced(cfg, layers=4, d_model=128, vocab=1024)

    # walk engine as the data pipeline
    g = powerlaw_temporal_graph(1000, 200_000, seed=3)
    eng = StreamingEngine(EngineConfig(
        window=WindowConfig(duration=3000, edge_capacity=1 << 16,
                            node_capacity=1024),
        sampler=SamplerConfig(bias="exponential", mode="index"),
        scheduler=SchedulerConfig()), batch_capacity=16384)
    batches = list(chronological_batches(g, 16))

    opt_cfg = AdamWConfig(lr=3e-4, warmup_steps=20, total_steps=args.steps)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    opt = init_opt_state(params, opt_cfg)
    step0 = 0
    if ckpt.latest_step(args.ckpt_dir) is not None:
        step0 = ckpt.latest_step(args.ckpt_dir)
        params = ckpt.restore(os.path.join(args.ckpt_dir, "params"), params)
        opt = ckpt.restore(os.path.join(args.ckpt_dir, "opt"), opt)
        print(f"restored checkpoint at step {step0}")

    train_step = jax.jit(make_train_step(cfg, opt_cfg))
    wcfg = WalkConfig(num_walks=1024, max_length=32, start_mode="nodes")

    bi = 0
    for step in range(step0, args.steps):
        if step % 20 == 0:                      # advance the stream
            bs, bd, bt = batches[bi % len(batches)]
            eng.ingest_batch(bs, bd, bt)
            bi += 1
        walks = eng.sample_walks(wcfg)
        toks, labels = walks_to_lm_batch(
            np.asarray(walks.nodes), np.asarray(walks.lengths),
            args.seq, args.batch, cfg.vocab_size, seed=step)
        params, opt, metrics = train_step(
            params, opt, {"tokens": toks, "labels": labels})
        if step % 20 == 0 or step == args.steps - 1:
            print(f"step {step:4d}: loss={float(metrics['loss']):.4f} "
                  f"lr={float(metrics['lr']):.2e} "
                  f"gnorm={float(metrics['grad_norm']):.3f}")
        if (step + 1) % 100 == 0:
            ckpt.save(os.path.join(args.ckpt_dir, "params"), params,
                      step + 1)
            ckpt.save(os.path.join(args.ckpt_dir, "opt"), opt, step + 1)
            ckpt.save(args.ckpt_dir, {"placeholder": np.zeros(1)}, step + 1)
            print(f"checkpointed at step {step + 1}")


if __name__ == "__main__":
    main()
