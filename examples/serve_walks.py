"""Multi-tenant walk-query serving over a live edge stream (DESIGN.md §11).

    PYTHONPATH=src python examples/serve_walks.py
    # serving at scale (DESIGN.md §13): shard the window over N devices
    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/serve_walks.py --shards 8

Three tenants with incompatible needs — different biases, fan-outs, walk
lengths, seeds — share every GPU dispatch: the coalescer packs their
queries into one shape-bucketed lane batch, and the per-lane RNG makes
each tenant's answer bit-identical to running it alone. With ``--shards``
the same service runs against the node-partitioned window: lanes start
on their owner shards and migrate per hop, and every tenant's answer
stays bit-identical to the single-device service's.
"""
import sys

import numpy as np

from repro.configs.base import (
    EngineConfig,
    SamplerConfig,
    SchedulerConfig,
    ServeConfig,
    WindowConfig,
)
from repro.data.synthetic import chronological_batches, powerlaw_temporal_graph
from repro.serve import WalkQuery, WalkService


def main():
    g = powerlaw_temporal_graph(num_nodes=1000, num_edges=50_000, seed=7)
    cfg = EngineConfig(
        window=WindowConfig(duration=4000, edge_capacity=1 << 16,
                            node_capacity=1024),
        sampler=SamplerConfig(mode="index"),       # bias is per-query now
        scheduler=SchedulerConfig(path="grouped"))
    svc = WalkService(cfg, ServeConfig(queue_capacity=256,
                                       lane_buckets=(64, 256, 1024),
                                       length_buckets=(8, 16, 32)),
                      batch_capacity=16384)

    batches = list(chronological_batches(g, 5))
    for bs, bd, bt in batches[:-1]:
        svc.ingest(bs, bd, bt)

    # three tenants, one dispatch
    recommender = WalkQuery(start_nodes=tuple(range(0, 48)),
                            bias="exponential", max_length=12, seed=101)
    fraud_team = WalkQuery(start_nodes=(7, 11, 13), bias="uniform",
                           max_length=30, seed=202)
    embedder = WalkQuery(num_walks=64, start_mode="edges", bias="linear",
                         start_bias="exponential", max_length=16, seed=303)
    tickets = {name: svc.submit(q, strict=True) for name, q in
               [("recommender", recommender), ("fraud", fraud_team),
                ("embedder", embedder)]}
    while svc.pending_count:
        svc.step()
    results = {}
    for name, t in tickets.items():
        r = results[name] = svc.poll(t)
        lens = r.lengths
        print(f"{name:12s} bias={r.query.bias:11s} walks={len(lens):3d} "
              f"mean_len={lens.mean():5.2f} latency={1e3*r.latency_s:6.1f}ms")

    # coalesced == solo, bit for bit (the §11 guarantee)
    solo_nodes, _, solo_lengths = svc.run_query_solo(fraud_team)
    assert np.array_equal(solo_nodes, results["fraud"].nodes)
    assert np.array_equal(solo_lengths, results["fraud"].lengths)
    print("fraud tenant: solo run == coalesced run, bit for bit")

    # snapshot double-buffer: keep serving the current window while the
    # next batch ingests; publish() swaps atomically
    bs, bd, bt = batches[-1]
    svc.begin_ingest(bs, bd, bt)
    t = svc.submit(recommender, strict=True)     # runs against old window
    svc.step()
    svc.poll(t)
    svc.publish()                                # new window from here on
    print(f"snapshot version={svc.snapshots.version} "
          f"(served 1 query mid-ingest)")

    s = svc.stats
    print(f"\nserved {s.completed} queries in {s.batches} batches "
          f"(occupancy {s.lane_occupancy:.0%}), p50={s.p50_ms:.1f}ms "
          f"p99={s.p99_ms:.1f}ms, {s.walks_per_s:.0f} walks/s")

    return svc, batches, [recommender, fraud_team, embedder]


def main_sharded(num_shards: int):
    """Re-run the three tenants over the node-partitioned window and show
    the DESIGN.md §13 invariant: sharded-coalesced == single-device solo.
    """
    from repro.configs.base import ShardConfig
    if num_shards < 1:
        raise SystemExit("--shards needs a positive shard count, e.g. "
                         "--shards 8")
    svc, batches, tenants = main()
    cfg = EngineConfig(
        window=WindowConfig(duration=4000, edge_capacity=1 << 16,
                            node_capacity=1024),
        sampler=SamplerConfig(mode="index"),
        scheduler=SchedulerConfig(path="grouped"),
        # exchange buckets must cover one sender routing its whole batch
        # slice to one owner (DESIGN.md §12 provisioning): at D=1 that is
        # the full 16384-row batch
        shard=ShardConfig(edge_capacity_per_shard=1 << 16,
                          exchange_capacity=1 << 14,
                          walk_slots=1 << 11, walk_bucket_capacity=1 << 10))
    sharded = WalkService(cfg, ServeConfig(queue_capacity=256,
                                           lane_buckets=(64, 256, 1024),
                                           length_buckets=(8, 16, 32)),
                          batch_capacity=16384, num_shards=num_shards)
    for bs, bd, bt in batches:
        sharded.ingest(bs, bd, bt)
    # the single-device service above only ingested batches[:-1] + [-1]
    # via begin/publish, i.e. all of them — same window version here
    tickets = [sharded.submit(q, strict=True) for q in tenants]
    while sharded.pending_count:
        sharded.step()
    for q, t in zip(tenants, tickets):
        r = sharded.poll(t)
        sn, _, sl = svc.run_query_solo(q)
        assert np.array_equal(r.nodes, sn) and np.array_equal(r.lengths, sl)
    print(f"\n{num_shards}-shard service: all {len(tenants)} tenants "
          f"bit-identical to single-device solo runs "
          f"(walk drops={sharded.stats.shard_walk_drops}, "
          f"ingest drops={sharded.stats.exchange_drops}, "
          f"lane balance={sharded.stats.lanes_by_shard})")


if __name__ == "__main__":
    if "--shards" in sys.argv[1:]:
        i = sys.argv.index("--shards")
        main_sharded(int(sys.argv[i + 1]) if len(sys.argv) > i + 1 else 0)
    else:
        main()
