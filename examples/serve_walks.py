"""Multi-tenant walk-query serving over a live edge stream (DESIGN.md §11).

    PYTHONPATH=src python examples/serve_walks.py

Three tenants with incompatible needs — different biases, fan-outs, walk
lengths, seeds — share every GPU dispatch: the coalescer packs their
queries into one shape-bucketed lane batch, and the per-lane RNG makes
each tenant's answer bit-identical to running it alone.
"""
import numpy as np

from repro.configs.base import (
    EngineConfig,
    SamplerConfig,
    SchedulerConfig,
    ServeConfig,
    WindowConfig,
)
from repro.data.synthetic import chronological_batches, powerlaw_temporal_graph
from repro.serve import WalkQuery, WalkService


def main():
    g = powerlaw_temporal_graph(num_nodes=1000, num_edges=50_000, seed=7)
    cfg = EngineConfig(
        window=WindowConfig(duration=4000, edge_capacity=1 << 16,
                            node_capacity=1024),
        sampler=SamplerConfig(mode="index"),       # bias is per-query now
        scheduler=SchedulerConfig(path="grouped"))
    svc = WalkService(cfg, ServeConfig(queue_capacity=256,
                                       lane_buckets=(64, 256, 1024),
                                       length_buckets=(8, 16, 32)),
                      batch_capacity=16384)

    batches = list(chronological_batches(g, 5))
    for bs, bd, bt in batches[:-1]:
        svc.ingest(bs, bd, bt)

    # three tenants, one dispatch
    recommender = WalkQuery(start_nodes=tuple(range(0, 48)),
                            bias="exponential", max_length=12, seed=101)
    fraud_team = WalkQuery(start_nodes=(7, 11, 13), bias="uniform",
                           max_length=30, seed=202)
    embedder = WalkQuery(num_walks=64, start_mode="edges", bias="linear",
                         start_bias="exponential", max_length=16, seed=303)
    tickets = {name: svc.submit(q, strict=True) for name, q in
               [("recommender", recommender), ("fraud", fraud_team),
                ("embedder", embedder)]}
    while svc.pending_count:
        svc.step()
    results = {}
    for name, t in tickets.items():
        r = results[name] = svc.poll(t)
        lens = r.lengths
        print(f"{name:12s} bias={r.query.bias:11s} walks={len(lens):3d} "
              f"mean_len={lens.mean():5.2f} latency={1e3*r.latency_s:6.1f}ms")

    # coalesced == solo, bit for bit (the §11 guarantee)
    solo_nodes, _, solo_lengths = svc.run_query_solo(fraud_team)
    assert np.array_equal(solo_nodes, results["fraud"].nodes)
    assert np.array_equal(solo_lengths, results["fraud"].lengths)
    print("fraud tenant: solo run == coalesced run, bit for bit")

    # snapshot double-buffer: keep serving the current window while the
    # next batch ingests; publish() swaps atomically
    bs, bd, bt = batches[-1]
    svc.begin_ingest(bs, bd, bt)
    t = svc.submit(recommender, strict=True)     # runs against old window
    svc.step()
    svc.poll(t)
    svc.publish()                                # new window from here on
    print(f"snapshot version={svc.snapshots.version} "
          f"(served 1 query mid-ingest)")

    s = svc.stats
    print(f"\nserved {s.completed} queries in {s.batches} batches "
          f"(occupancy {s.lane_occupancy:.0%}), p50={s.p50_ms:.1f}ms "
          f"p99={s.p99_ms:.1f}ms, {s.walks_per_s:.0f} walks/s")


if __name__ == "__main__":
    main()
