"""Example: batched autoregressive serving with the KV-cache serve step.

    PYTHONPATH=src python examples/serve_lm.py --arch qwen2-0.5b
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced
from repro.models import model as M
from repro.train.train_loop import make_serve_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--steps", type=int, default=32)
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full:
        cfg = reduced(cfg)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    serve = jax.jit(make_serve_step(cfg))

    state = M.init_decode_state(cfg, args.batch, args.steps + 8)
    tok = jnp.ones((args.batch, 1), jnp.int32)
    outs = []
    t0 = time.perf_counter()
    for _ in range(args.steps):
        tok, state = serve(params, tok, state)
        outs.append(np.asarray(tok)[:, 0])
    dt = time.perf_counter() - t0
    seqs = np.stack(outs, 1)
    print(f"arch={cfg.name} batch={args.batch} steps={args.steps}")
    print(f"throughput: {args.batch*args.steps/dt:.1f} tok/s "
          f"({1e3*dt/args.steps:.1f} ms/step)")
    print("sampled ids (greedy):", seqs[0][:16], "...")


if __name__ == "__main__":
    main()
