"""End-to-end driver #1: streaming walks -> incremental CTDNE-style
skipgram embeddings -> temporal link prediction (paper §3.9).

    PYTHONPATH=src python examples/train_embeddings.py
"""
import jax
import numpy as np

from repro.configs.base import (
    EngineConfig,
    SamplerConfig,
    SchedulerConfig,
    WalkConfig,
    WindowConfig,
)
from repro.core.streaming import StreamingEngine
from repro.data.synthetic import chronological_batches, powerlaw_temporal_graph
from repro.train.embeddings import (
    init_skipgram,
    link_prediction_auc,
    train_on_walks,
)


def main(num_nodes=512, num_edges=50_000, batches=20, dim=64):
    g = powerlaw_temporal_graph(num_nodes, num_edges, seed=21)
    n_test = int(0.85 * num_edges)
    cfg = EngineConfig(
        window=WindowConfig(duration=(int(g.ts.max()) + 1) / batches * 2,
                            edge_capacity=1 << 16,
                            node_capacity=num_nodes),
        sampler=SamplerConfig(bias="exponential", mode="index"),
        scheduler=SchedulerConfig(),
    )
    eng = StreamingEngine(cfg, batch_capacity=num_edges // batches + 64)
    state = init_skipgram(num_nodes, dim, jax.random.PRNGKey(1))
    key = jax.random.PRNGKey(2)
    wcfg = WalkConfig(num_walks=2048, max_length=12, start_mode="nodes")

    for bi, (bs, bd, bt) in enumerate(chronological_batches(g, batches)):
        if bi / batches > 0.7:
            break                              # chronological train split
        eng.ingest_batch(bs, bd, bt)
        walks = eng.sample_walks(wcfg)
        key, sub = jax.random.split(key)
        state, loss = train_on_walks(state, walks.nodes, walks.lengths,
                                     sub, epochs=1)
        auc = link_prediction_auc(state, g.src[n_test:], g.dst[n_test:],
                                  num_nodes)
        print(f"batch {bi:2d}: skipgram_loss={loss:.4f} test_auc={auc:.3f}")

    print("\nfinal test AUC:",
          link_prediction_auc(state, g.src[n_test:], g.dst[n_test:],
                              num_nodes))


if __name__ == "__main__":
    main()
